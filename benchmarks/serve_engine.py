"""Serving-engine benchmark: scheduling policy AND execution path.

Two orthogonal comparisons, all sharing the per-slot cache machinery (so
each axis is isolated):

  * **scheduling** — the continuous-batching engine (fixed pool of K << N
    slots, FIFO admission, slot recycling) vs the **static** whole-batch
    baseline (every request gets its own lane up front; the decode batch
    stays N-wide until the longest request finishes). On a skewed
    log-uniform trace the static batch decays to a nearly-empty wide batch
    while the engine keeps occupancy high.
  * **execution** — the engine's device-resident **fast** path (fused
    decode horizons, batched multi-slot prefill, donated pooled cache) vs
    the stepwise **slow** reference (one dispatch + one host sync per
    generated token), swept over ``--decode-horizon``.
  * **KV precision** — every (model, params) variant is additionally served
    through the int8 pooled KV cache (``kv_bits=8``: int8 payload +
    per-token/per-head scales, decode through the kv_attention op), with
    its own fast-vs-stepwise parity assert; the ``kv8_vs_fp`` summary
    records the steady-trace tok/s ratio and the KV bytes/slot reduction.
  * **topology** — when jax sees >= 8 devices (the bench-smoke CI job forces
    8 virtual CPU devices), the top-horizon fast engine is additionally run
    over a 2x4 ("data", "model") mesh and parity-asserted token-for-token
    against its single-device twin. On CPU the collectives are pure
    overhead, so the recorded ``sharded_vs_single`` ratio tracks sharding
    TAX, not speedup — the point is that the deployment topology is
    exercised (and its tokens pinned) continuously. Caveat: FORCING virtual
    devices shrinks each CPU device's thread pool, which re-partitions
    matmul reductions differently across compiled programs — at the full
    (non-smoke) dims that float-level wobble can flip a greedy argmax deep
    into the 177-step steady decode and trip the parity asserts. Run the
    full bench on real topology or single-device; the virtual-device recipe
    is for --smoke (what CI does).

Each comparison runs on the regime it targets, over two traces per variant:

  * **mixed** — skewed log-uniform lengths, high slot churn: the
    continuous-batching stress case (headline for the scheduling win; the
    adaptive horizon spends much of its time capped by imminent
    retire/admit/prefill events, so sync amortization is modest here).
  * **steady** — one wave of uniform decode-heavy requests: the classic
    serving-throughput regime where fused horizons amortize fully (headline
    for the host-sync reduction).

Every variant must emit bit-identical tokens per trace — the parity assert
is the whole contract of the fast path.

Results are persisted to ``BENCH_serve.json`` (tok/s, speedups, occupancy,
host-sync and dispatch counts per token) so the perf trajectory is tracked
across PRs.

    PYTHONPATH=src python benchmarks/serve_engine.py
    PYTHONPATH=src python benchmarks/serve_engine.py --smoke   # tiny dims (CI)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax

import repro
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine, synthetic_trace

DEFAULT_JSON = pathlib.Path(__file__).resolve().parent / "BENCH_serve.json"

HORIZONS = (1, 4, 8)


def make_setup(smoke: bool) -> dict:
    """Benchmark dims. Default: mid-size so decode cost scales with batch
    width on CPU (pure smoke dims are dispatch-bound, which would mask the
    scheduling win). ``smoke``: tiny dims for the CI smoke-benchmark job."""
    if smoke:
        cfg = dataclasses.replace(
            get_config("qwen2-0.5b", smoke=True),
            name="qwen2-serve-bench-smoke",
        )
        return {"cfg": cfg, "n_requests": 8, "slots": 4, "prefill_chunk": 8,
                "prompt_lens": (4, 16), "gen_lens": (4, 24),
                "steady_prompt": 8, "steady_gen": 25, "max_len": 48}
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b", smoke=True),
        name="qwen2-serve-bench",
        n_layers=4, d_model=256, n_heads=8, head_dim=32, n_kv_heads=2,
        d_ff=1024, vocab_size=2048, max_seq=256,
    )
    # max_len fits max(ceil(32/16)*16, 32+64-1) and steady 16+177-1; the
    # steady trace decodes deep into the ring so the KV stream (what kv8
    # targets) is a visible fraction of the step, not just the dense GEMMs
    return {"cfg": cfg, "n_requests": 24, "slots": 8, "prefill_chunk": 16,
            "prompt_lens": (4, 32), "gen_lens": (4, 64),
            "steady_prompt": 16, "steady_gen": 177, "max_len": 192}


def _run(engine: ServingEngine, trace, repeats: int = 3) -> dict:
    """Serve ``trace`` ``repeats`` times on a warmed engine (best-of-3: CPU
    wall noise swamps best-of-2 at these dims); returns the
    best-timed run's tokens/s (CPU wall noise) + efficiency counters
    (per-token host syncs and device dispatches). Repeats double as a
    determinism check — every run must produce identical tokens."""
    best = None
    for _ in range(repeats):
        base = dict(engine.stats)
        t0 = time.perf_counter()
        results = engine.run([dataclasses.replace(r) for r in trace])
        dt = time.perf_counter() - t0
        d = {k: engine.stats[k] - base[k] for k in base}
        gen = max(d["generated_tokens"], 1)
        row = {
            "tok_s": d["generated_tokens"] / dt,
            "seconds": dt,
            "decode_steps": d["decode_steps"],
            "occupancy": d["occupancy_sum"] / max(d["engine_steps"], 1),
            "host_syncs_per_token": d["host_syncs"] / gen,
            "dispatches_per_token":
                (d["decode_dispatches"] + d["prefill_dispatches"]) / gen,
            "tokens": {r.rid: tuple(r.tokens) for r in results.values()},
        }
        if best is not None:
            assert row["tokens"] == best["tokens"], "non-deterministic serve"
        if best is None or row["tok_s"] > best["tok_s"]:
            best = row
    return best


def bench_variant(label: str, model, params, setup: dict, *,
                  kv_bits=None, full: bool = True) -> dict:
    """All scheduling/execution variants for one (model, params), over the
    mixed (churny) and steady (decode-dominant) traces; asserts bit-exact
    token parity across the board. ``kv_bits=8`` serves through the int8
    pooled KV cache; ``full=False`` runs only the slow reference and the
    top-horizon fast path (the kv8 comparison points)."""
    cfg = setup["cfg"]
    traces = {
        "mixed": synthetic_trace(
            0, setup["n_requests"], vocab_size=cfg.vocab_size,
            prompt_lens=setup["prompt_lens"], gen_lens=setup["gen_lens"]),
        "steady": synthetic_trace(
            0, setup["slots"], vocab_size=cfg.vocab_size,
            prompt_lens=(setup["steady_prompt"],) * 2,
            gen_lens=(setup["steady_gen"],) * 2),
    }

    variants = {"slow": dict(num_slots=setup["slots"], fast=False)}
    if full:
        variants["static"] = dict(num_slots=setup["n_requests"], fast=True)
    for h in (HORIZONS if full else (max(HORIZONS),)):
        variants[f"fast_h{h}"] = dict(num_slots=setup["slots"], fast=True,
                                      decode_horizon=h)
    rows = {}
    bytes_per_slot = None
    for mode, kw in variants.items():
        eng = ServingEngine(model, params, cfg, max_len=setup["max_len"],
                            prefill_chunk=setup["prefill_chunk"],
                            kv_bits=kv_bits, **kw)
        if mode != "static":
            bytes_per_slot = eng.pool.bytes_per_slot()
        eng.warmup()   # compile all pow2 prefill/horizon shapes up front
        rows[mode] = {tname: _run(eng, trace)
                      for tname, trace in traces.items()}
    # parity guard: neither the scheduling policy nor the execution path may
    # change a single generated token
    for tname in traces:
        ref = rows["slow"][tname]["tokens"]
        for mode in rows:
            assert rows[mode][tname]["tokens"] == ref, (
                f"{label}/{mode}/{tname}: generated tokens diverged from "
                f"the stepwise reference — fast-path/batch invariance broken"
            )
    for mode in rows:
        for tname in traces:
            del rows[mode][tname]["tokens"]

    best = f"fast_h{max(HORIZONS)}"
    swept = HORIZONS if full else (max(HORIZONS),)

    def best_fast(tname):   # best horizon of the sweep, per trace
        return max(rows[f"fast_h{h}"][tname]["tok_s"] for h in swept)

    out = {
        "label": label,
        "kv_bits": kv_bits or 16,
        "kv_bytes_per_slot": bytes_per_slot,
        "variants": rows,
        # headline numbers, each on the regime its optimization targets;
        # tok/s speedups take the sweep's best horizon (that is what the
        # sweep is for), sync reductions are pinned at horizon 8
        "speedup_fast_vs_slow_mixed":
            best_fast("mixed") / rows["slow"]["mixed"]["tok_s"],
        "speedup_fast_vs_slow_steady":
            best_fast("steady") / rows["slow"]["steady"]["tok_s"],
        "sync_reduction_steady_h8":
            rows["slow"]["steady"]["host_syncs_per_token"]
            / max(rows[best]["steady"]["host_syncs_per_token"], 1e-9),
        "sync_reduction_mixed_h8":
            rows["slow"]["mixed"]["host_syncs_per_token"]
            / max(rows[best]["mixed"]["host_syncs_per_token"], 1e-9),
    }
    if full:
        out["speedup_engine_vs_static_mixed"] = (
            rows[best]["mixed"]["tok_s"] / rows["static"]["mixed"]["tok_s"])
    print(f"{label}:")
    for tname in traces:
        s, f = rows["slow"][tname], rows[best][tname]
        print(f"  {tname:6s} slow {s['tok_s']:8.1f} tok/s "
              f"({s['host_syncs_per_token']:.3f} syncs/tok)  |  "
              f"fast(h={max(HORIZONS)}) {f['tok_s']:8.1f} tok/s "
              f"({f['host_syncs_per_token']:.3f} syncs/tok)  |  "
              f"{f['tok_s'] / s['tok_s']:.2f}x tok/s, "
              f"{s['host_syncs_per_token'] / max(f['host_syncs_per_token'], 1e-9):.1f}x fewer syncs")
    if full:
        print(f"  engine vs static (mixed): "
              f"{out['speedup_engine_vs_static_mixed']:.2f}x tok/s at "
              f"occ {rows[best]['mixed']['occupancy']:.2f} vs "
              f"{rows['static']['mixed']['occupancy']:.2f} "
              f"with {setup['slots']} vs {setup['n_requests']} live KV slots")
    for h in swept:
        r = rows[f"fast_h{h}"]
        print(f"    h={h}: steady {r['steady']['tok_s']:8.1f} tok/s "
              f"({r['steady']['host_syncs_per_token']:.3f} syncs/tok), "
              f"mixed {r['mixed']['tok_s']:8.1f} tok/s "
              f"({r['mixed']['host_syncs_per_token']:.3f} syncs/tok)")
    return out


def bench_sharded(label: str, model, params, setup: dict, *,
                  kv_bits=None) -> dict:
    """Sharded-vs-single sweep for one (model, params): the top-horizon fast
    engine on a 2x4 ("data", "model") mesh vs single-device, both traces,
    tokens parity-asserted. Requires >= 8 jax devices (the bench-smoke job
    forces 8 virtual CPU devices via XLA_FLAGS)."""
    from repro.launch.mesh import make_production_mesh

    cfg = setup["cfg"]
    mesh = make_production_mesh(shape=(2, 4))
    traces = {
        "mixed": synthetic_trace(
            0, setup["n_requests"], vocab_size=cfg.vocab_size,
            prompt_lens=setup["prompt_lens"], gen_lens=setup["gen_lens"]),
        "steady": synthetic_trace(
            0, setup["slots"], vocab_size=cfg.vocab_size,
            prompt_lens=(setup["steady_prompt"],) * 2,
            gen_lens=(setup["steady_gen"],) * 2),
    }
    kw = dict(num_slots=setup["slots"], max_len=setup["max_len"],
              prefill_chunk=setup["prefill_chunk"], kv_bits=kv_bits,
              fast=True, decode_horizon=max(HORIZONS))
    out = {"label": label, "mesh_shape": [2, 4],
           "mesh_axes": ["data", "model"], "traces": {}}
    rows = {}
    # sequential build→warm→run→discard: both engines at once would hold two
    # full param placements + two KV pools at peak (matters at real dims)
    for mode in ("single", "sharded"):
        eng = ServingEngine(model, params, cfg,
                            mesh=mesh if mode == "sharded" else None, **kw)
        eng.warmup()
        rows[mode] = {t: _run(eng, trace, repeats=2)
                      for t, trace in traces.items()}
        del eng
    for tname in traces:
        assert (rows["sharded"][tname]["tokens"]
                == rows["single"][tname]["tokens"]), (
            f"{label}/{tname}: sharded tokens diverged from single-device"
        )
        out["traces"][tname] = {
            "tok_s_single": rows["single"][tname]["tok_s"],
            "tok_s_sharded": rows["sharded"][tname]["tok_s"],
            "sharded_vs_single":
                rows["sharded"][tname]["tok_s"]
                / rows["single"][tname]["tok_s"],
        }
        r = out["traces"][tname]
        print(f"  sharded 2x4 {label}/{tname}: "
              f"{r['tok_s_sharded']:8.1f} vs single "
              f"{r['tok_s_single']:8.1f} tok/s "
              f"({r['sharded_vs_single']:.2f}x, tokens identical)")
    return out


def bench_prefix_capacity(label: str, model, params, setup: dict, *,
                          kv_bits=None) -> dict:
    """The paged-cache headline: concurrent-slot capacity at EQUAL cache
    memory on a shared-prefix workload.

    Both engines get the same payload byte budget (asserted): the contiguous
    pool spends it on 2 full-ring slots; the paged pool spends it on the
    equivalent page pool (2 * ring/page pages) shared by ring/page + 1 slot
    tables. The trace is one donor plus ring/page followers with an
    identical long prompt, arriving right after the donor's prefill
    publishes its prompt pages — each follower then maps the shared pages
    and pays ONE fresh page, so the paged engine holds every request
    resident at once while the contiguous engine admits two at a time.
    Tokens are parity-asserted across layouts; the recorded
    ``capacity_ratio`` (peak concurrent slots, paged / contiguous) is the
    acceptance number (>= 2x)."""
    cfg = setup["cfg"]
    pg = setup["prefill_chunk"]          # page == chunk: aligned reuse
    ring = setup["max_len"]
    pps = ring // pg                     # pages per full-ring slot
    flat_slots = 2
    paged_slots = pps + 1
    prompt = synthetic_trace(
        5, 1, vocab_size=cfg.vocab_size,
        prompt_lens=(pps * pg - 2,) * 2, gen_lens=(3, 3))[0].prompt
    trace = [Request(
        rid=i, prompt=prompt, max_new_tokens=3,
        arrival=0.0 if i == 0 else pps + 0.5)
        for i in range(paged_slots)]

    def drive(engine):
        # high-water mark of concurrently allocated slots, sampled at
        # allocation time (a follower's whole lifetime — one-chunk prefill +
        # short decode — can fit inside ONE fused engine step, so sampling
        # between steps would miss the peak)
        peak = {"n": 0}
        pool = engine.pool
        real = pool.allocate_pages if pool.paged else pool.allocate

        def counting(*a, **kw):
            out = real(*a, **kw)
            peak["n"] = max(peak["n"], pool.n_allocated)
            return out

        if pool.paged:
            pool.allocate_pages = counting
        else:
            pool.allocate = counting
        t0 = time.perf_counter()
        for r in trace:
            engine.submit(dataclasses.replace(r))
        while engine.scheduler.pending() or engine._inflight:
            engine.step()
        dt = time.perf_counter() - t0
        out, engine.results = engine.results, {}
        return out, peak["n"], dt

    kw = dict(max_len=ring, prefill_chunk=pg, fast=True, kv_bits=kv_bits,
              decode_horizon=max(HORIZONS))
    flat_eng = ServingEngine(model, params, cfg, num_slots=flat_slots, **kw)
    paged_eng = ServingEngine(model, params, cfg, num_slots=paged_slots,
                              page_size=pg, num_pages=flat_slots * pps, **kw)
    assert paged_eng.pool.cache_bytes() == flat_eng.pool.cache_bytes(), (
        "capacity comparison must hold cache memory equal")
    flat_res, flat_peak, flat_dt = drive(flat_eng)
    paged_res, paged_peak, paged_dt = drive(paged_eng)
    assert {r: v.tokens for r, v in paged_res.items()} == \
           {r: v.tokens for r, v in flat_res.items()}, (
        f"{label}: paged tokens diverged on the shared-prefix trace")
    ratio = paged_peak / flat_peak
    assert ratio >= 2.0, (
        f"{label}: paged peak {paged_peak} vs contiguous {flat_peak} slots "
        f"at equal memory — the shared-prefix capacity win regressed")
    out = {
        "label": label,
        "cache_bytes": flat_eng.pool.cache_bytes(),
        "page_size": pg, "num_pages": flat_slots * pps,
        "n_requests": len(trace), "prompt_len": len(prompt),
        "peak_slots_contiguous": flat_peak,
        "peak_slots_paged": paged_peak,
        "capacity_ratio": ratio,
        "prefix_hits": paged_eng.prefix_index.hits,
        "cow_copies": paged_eng.pool.cow_copies,
        "makespan_contiguous_s": flat_dt,
        "makespan_paged_s": paged_dt,
    }
    print(f"  prefix capacity {label}: paged {paged_peak} vs contiguous "
          f"{flat_peak} concurrent slots at {out['cache_bytes']} B "
          f"({ratio:.1f}x, {out['prefix_hits']} prefix hits, "
          f"{out['cow_copies']} COW copies, tokens identical)")
    return out


def bench_decode_dispatches(model, params, setup: dict) -> dict:
    """Kernel launches per decode step through the engine's OWN decode jit,
    fused megakernel vs stepwise (``REPRO_FUSED_DECODE`` on/off), counted
    from the traced jaxpr under the interpret tier — the exact ``pallas_call``
    count the TPU tier dispatches, measurable on any host. Uses the full
    int8 graph (w8a8 weights + int8 KV), where both the decode megakernel
    and the q8 GEMM epilogue engage."""
    import os

    from repro.kernels.dispatch import ENV_VAR, count_pallas_calls

    saved = {k: os.environ.get(k) for k in (ENV_VAR, "REPRO_FUSED_DECODE")}
    counts = {}
    try:
        os.environ[ENV_VAR] = "interpret"   # every op on its Pallas twin
        for mode, flag in (("fused", "1"), ("unfused", "0")):
            os.environ["REPRO_FUSED_DECODE"] = flag
            eng = ServingEngine(model, params, setup["cfg"],
                                num_slots=setup["slots"],
                                max_len=setup["max_len"],
                                prefill_chunk=setup["prefill_chunk"],
                                kv_bits=8)
            _, impl, args, kw = eng.serve_jit_specs()["decode"]
            counts[mode] = count_pallas_calls(impl, *args, **kw)
            del eng
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    out = {
        "dispatches_per_decode_step_fused": counts["fused"],
        "dispatches_per_decode_step_unfused": counts["unfused"],
        "decode_dispatch_reduction": counts["unfused"] / counts["fused"],
    }
    print(f"decode dispatches/step (w8a8-kv8, trace-counted): "
          f"{counts['unfused']} stepwise -> {counts['fused']} fused "
          f"({out['decode_dispatch_reduction']:.2f}x fewer launches)")
    return out


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dims for the CI smoke-benchmark job")
    ap.add_argument("--json", default=str(DEFAULT_JSON), metavar="PATH",
                    help="where to persist machine-readable results")
    args = ap.parse_args(argv)

    setup = make_setup(args.smoke)
    cfg = setup["cfg"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    print(f"mixed trace: {setup['n_requests']} requests, "
          f"prompt {setup['prompt_lens']}, gen {setup['gen_lens']} "
          f"(log-uniform), closed arrivals; steady trace: {setup['slots']} x "
          f"prompt {setup['steady_prompt']} / gen {setup['steady_gen']}; "
          f"horizons {HORIZONS}")
    results = [bench_variant("fp32", model, params, setup)]
    results.append(bench_variant("fp32-kv8", model, params, setup,
                                 kv_bits=8, full=False))

    qm = repro.quantize(model, params=params, recipe="serve-w8a16")
    results.append(bench_variant("serve-w8a16", qm.model, qm.params, setup))
    # the kv_cache stage is weight-free — the same packed params serve the
    # int8-KV engine (what the serve-w8a16-kv8 recipe produces)
    results.append(bench_variant("serve-w8a16-kv8", qm.model, qm.params,
                                 setup, kv_bits=8, full=False))

    qm8 = repro.quantize(model, params=params, recipe="serve-w8a8-kv8")
    dispatches = bench_decode_dispatches(qm8.model, qm8.params, setup)

    kv8 = _kv8_summary(results)
    for fp_label, row in kv8.items():
        print(f"kv8 vs fp ({fp_label}): "
              f"steady {row['speedup_kv8_vs_fp_steady']:.2f}x tok/s, "
              f"mixed {row['speedup_kv8_vs_fp_mixed']:.2f}x, "
              f"{row['kv_bytes_reduction']:.2f}x fewer KV bytes/slot "
              f"({row['kv_bytes_per_slot_fp']} -> "
              f"{row['kv_bytes_per_slot_kv8']} B)")

    print("shared-prefix capacity at equal cache memory (paged vs "
          "contiguous):")
    capacity = [
        bench_prefix_capacity("fp32", model, params, setup),
        bench_prefix_capacity("serve-w8a16-kv8", qm.model, qm.params, setup,
                              kv_bits=8),
    ]

    sharded = []
    # >1 CPU device only happens when virtual devices are FORCED — at full
    # dims that repartitions matmul reductions enough to flip deep-decode
    # argmaxes and trip the parity asserts (module docstring), so the full
    # sweep only runs on real multi-device topology
    forced_virtual = jax.default_backend() == "cpu"
    if jax.device_count() >= 8 and (args.smoke or not forced_virtual):
        print("sharded sweep (2x4 mesh, tokens parity-asserted):")
        sharded.append(bench_sharded("fp32", model, params, setup))
        sharded.append(bench_sharded("serve-w8a16", qm.model, qm.params,
                                     setup))
        sharded.append(bench_sharded("serve-w8a16-kv8", qm.model, qm.params,
                                     setup, kv_bits=8))
    elif jax.device_count() >= 8:
        print("sharded sweep skipped: full dims on forced virtual CPU "
              "devices break cross-program bit parity (see module "
              "docstring); run with --smoke or on real topology")
    else:
        print(f"sharded sweep skipped: {jax.device_count()} device(s); set "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=8 and "
              f"--smoke")

    write_bench_json(args.json, results, setup, kv8, sharded=sharded,
                     capacity=capacity, dispatches=dispatches,
                     smoke=args.smoke)
    return results


def _kv8_summary(results: list[dict]) -> dict:
    """Cross-label fp-vs-kv8 headline: tok/s ratio at the top horizon and
    the KV bytes/slot reduction (both paths individually parity-asserted
    against their own stepwise reference in bench_variant)."""
    by = {r["label"]: r for r in results}
    best = f"fast_h{max(HORIZONS)}"
    out = {}
    for fp_label in ("fp32", "serve-w8a16"):
        kv8_label = f"{fp_label}-kv8"
        if fp_label not in by or kv8_label not in by:
            continue
        fp, k8 = by[fp_label], by[kv8_label]
        out[fp_label] = {
            "steady_tok_s_fp": fp["variants"][best]["steady"]["tok_s"],
            "steady_tok_s_kv8": k8["variants"][best]["steady"]["tok_s"],
            "speedup_kv8_vs_fp_steady":
                k8["variants"][best]["steady"]["tok_s"]
                / fp["variants"][best]["steady"]["tok_s"],
            "speedup_kv8_vs_fp_mixed":
                k8["variants"][best]["mixed"]["tok_s"]
                / fp["variants"][best]["mixed"]["tok_s"],
            "kv_bytes_per_slot_fp": fp["kv_bytes_per_slot"],
            "kv_bytes_per_slot_kv8": k8["kv_bytes_per_slot"],
            "kv_bytes_reduction":
                fp["kv_bytes_per_slot"] / k8["kv_bytes_per_slot"],
        }
    return out


def write_bench_json(path, results: list[dict], setup: dict,
                     kv8: dict = None, sharded: list = None,
                     capacity: list = None, dispatches: dict = None,
                     smoke: bool = False) -> None:
    payload = {
        "benchmark": "serve_engine",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "smoke": smoke,
        "sharded": sharded or [],
        "prefix_capacity": capacity or [],
        "decode_dispatches": dispatches or {},
        "traces": {
            "mixed": {"n_requests": setup["n_requests"],
                      "prompt_lens": list(setup["prompt_lens"]),
                      "gen_lens": list(setup["gen_lens"])},
            "steady": {"n_requests": setup["slots"],
                       "prompt_len": setup["steady_prompt"],
                       "gen_len": setup["steady_gen"]},
        },
        "slots": setup["slots"],
        "prefill_chunk": setup["prefill_chunk"],
        "horizons": list(HORIZONS),
        "kv8_vs_fp": kv8 if kv8 is not None else _kv8_summary(results),
        "results": results,
    }
    p = pathlib.Path(path)
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {p}")


def serve_rows(json_path=None):
    """benchmarks.run harness adapter: (name, value) CSV rows; persists the
    full payload to BENCH_serve.json as a side effect."""
    path = pathlib.Path(json_path) if json_path else DEFAULT_JSON
    results = main(["--json", str(path)])
    rows = []
    for k, v in json.loads(path.read_text())["decode_dispatches"].items():
        rows.append((f"fused_decode.{k}", v))
    for r in results:
        fast = r["variants"][f"fast_h{max(HORIZONS)}"]
        rows.append((f"{r['label']}.fast_tok_s_mixed",
                     round(fast["mixed"]["tok_s"], 1)))
        rows.append((f"{r['label']}.speedup_fast_vs_slow_mixed",
                     round(r["speedup_fast_vs_slow_mixed"], 3)))
        rows.append((f"{r['label']}.speedup_fast_vs_slow_steady",
                     round(r["speedup_fast_vs_slow_steady"], 3)))
        rows.append((f"{r['label']}.sync_reduction_steady_h8",
                     round(r["sync_reduction_steady_h8"], 2)))
        if "speedup_engine_vs_static_mixed" in r:
            rows.append((f"{r['label']}.speedup_vs_static_mixed",
                         round(r["speedup_engine_vs_static_mixed"], 3)))
        rows.append((f"{r['label']}.mean_occupancy_mixed",
                     round(fast["mixed"]["occupancy"], 3)))
    for fp_label, row in _kv8_summary(results).items():
        rows.append((f"{fp_label}.kv8_speedup_steady",
                     round(row["speedup_kv8_vs_fp_steady"], 3)))
        rows.append((f"{fp_label}.kv8_bytes_reduction",
                     round(row["kv_bytes_reduction"], 3)))
    return rows


if __name__ == "__main__":
    main()
