"""Shared pipeline for the paper-faithful CNN benchmarks.

Trains (once, cached) a MobileNetV2-style CNN on the synthetic classification
task, then injects **adversarial per-channel scales** through the same
positive-scaling equivariance DFQ exploits — the FP32 function is exactly
unchanged, but per-tensor INT8 collapses, reproducing the paper's
MobileNetV2 starting point (Table 1 row 1: 0.1 % top-1) without the original
ImageNet checkpoint. All tables/figures then measure recovery.
"""
from __future__ import annotations

import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DFQConfig,
    QuantSpec,
    fake_quant,
    qparams_from_range,
    fake_quant_with_qparams,
)
from repro.data import synthetic_image_batch
from repro.models.cnn import CNNConfig, MobileNetCNN
from repro.optim import adamw_init, adamw_update

CACHE = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
CLASSES = 8
IMG = 32


def _train(model, steps=300, batch=128, seed=0):
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, new_params), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(params)
        upd, opt2, _ = adamw_update(grads, opt, params, lr=3e-3, weight_decay=1e-4)
        # keep the BN running stats from the fwd pass, trained weights from AdamW
        merged = jax.tree.map(lambda a, b: b, upd, upd)
        merged = _merge_bn(upd, new_params)
        return merged, opt2, loss

    for s in range(steps):
        b = synthetic_image_batch(seed, s, batch, IMG, 3, CLASSES)
        params, opt, loss = step(params, opt, b)
    return params, float(loss)


def _merge_bn(trained, with_stats):
    """Take mean/var from the fwd-updated tree, everything else from AdamW."""
    def merge(path_a, a, b):
        return b
    def walk(t, w):
        if isinstance(t, dict):
            return {k: (walk(t[k], w[k]) if k in w else t[k]) for k in t}
        if isinstance(t, list):
            return [walk(a, b) for a, b in zip(t, w)]
        return t
    # BN dicts contain mean/var keys; replace them from with_stats
    def fix(t, w):
        if isinstance(t, dict):
            if set(t) == {"gamma", "beta", "mean", "var"}:
                return {"gamma": t["gamma"], "beta": t["beta"],
                        "mean": w["mean"], "var": w["var"]}
            return {k: fix(t[k], w[k]) for k in t}
        if isinstance(t, list):
            return [fix(a, b) for a, b in zip(t, w)]
        return t
    return fix(trained, with_stats)


def get_trained_cnn(force=False):
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, "cnn_params.pkl")
    cfg = CNNConfig(num_classes=CLASSES, img_size=IMG)
    model = MobileNetCNN(cfg)
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            params = pickle.load(f)
        return model, jax.tree.map(jnp.asarray, params)
    params, loss = _train(model)
    with open(path, "wb") as f:
        pickle.dump(jax.device_get(params), f)
    return model, params


def adversarial_rescale(folded, seed=0, decades=1.5):
    """Function-preserving random per-channel rescale over each inverted
    residual's (expand → dw → project) chain — the hostile-ranges injector."""
    import copy

    from repro.core.cle import ConvLayer, _scale_in, _scale_out

    folded = copy.deepcopy(jax.device_get(folded))
    key = jax.random.PRNGKey(seed)
    for i, blk in enumerate(folded["blocks"]):
        for j, (src, dst, dst_kind) in enumerate(
            (("expand", "dw", "depthwise"), ("dw", "project", "conv"))
        ):
            key, k = jax.random.split(key)
            c = folded["blocks"][i][src].w.shape[-1]
            s = jnp.exp(jax.random.normal(k, (c,)) * decades)
            l1 = ConvLayer(jnp.asarray(blk[src].w), jnp.asarray(blk[src].b),
                           "depthwise" if src == "dw" else "conv")
            l2 = ConvLayer(jnp.asarray(blk[dst].w),
                           None if blk[dst].b is None else jnp.asarray(blk[dst].b),
                           dst_kind)
            l1s = _scale_out(l1, s)
            l2s = _scale_in(l2, s)
            blk[src] = blk[src]._replace(
                w=l1s.w, b=l1s.b,
                act_mean=jnp.asarray(blk[src].act_mean) / s,
                act_std=jnp.asarray(blk[src].act_std) / s,
            )
            blk[dst] = blk[dst]._replace(w=l2s.w)
    return folded


def eval_accuracy(model, folded, *, act_clip=None, act_bits=None,
                  act_symmetric=False, n_batches=8, seed=99, n_sigma=6.0):
    """Top-1 on held-out synthetic batches; optional data-free activation
    fake-quant with β ± 6γ ranges (paper §5)."""
    act_quant = None
    if act_bits is not None:
        spec = QuantSpec(bits=act_bits, symmetric=act_symmetric)

        def act_quant(h, name, mean, std):
            lo = jnp.minimum(jnp.min(mean - n_sigma * std), 0.0)
            lo = jnp.maximum(lo, 0.0)  # post-ReLU: clip min to 0 (paper §5)
            hi = jnp.max(mean + n_sigma * std)
            if act_clip is not None:
                hi = jnp.minimum(hi, act_clip)
            qp = qparams_from_range(lo, hi, spec)
            return fake_quant_with_qparams(h, qp)

    correct = total = 0
    for i in range(n_batches):
        b = synthetic_image_batch(seed, 10_000 + i, 256, IMG, 3, CLASSES)
        logits = model.apply_folded(folded, b["x"], act_clip=act_clip,
                                    act_quant=act_quant)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == b["y"]))
        total += 256
    return correct / total


def clip_weights(folded, clip=15.0):
    """Paper §5.1.2 weight-clipping baseline."""
    import copy

    q = copy.deepcopy(jax.device_get(folded))
    def cl(w):
        return jnp.clip(jnp.asarray(w), -clip, clip)
    q["stem"] = q["stem"]._replace(w=cl(q["stem"].w))
    for blk in q["blocks"]:
        for k in ("expand", "dw", "project"):
            blk[k] = blk[k]._replace(w=cl(blk[k].w))
    return q
