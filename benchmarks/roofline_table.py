"""Aggregate results/dryrun/*.json into the §Roofline table (markdown + CSV
rows for benchmarks.run)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(mesh="single", tag=""):
    cells = {}
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}{tag}.json"))):
        name = os.path.basename(path).replace(f"__{mesh}{tag}.json", "")
        with open(path) as f:
            cells[name] = json.load(f)
    return cells


def what_moves_it(r, cell):
    dom = r["dominant"]
    if dom == "compute":
        return "cut remat recompute / int8 MXU for the quantized path"
    if dom == "memory":
        return "quantize weights+cache (W8A16 halves HBM bytes) / larger per-step batch"
    return "reduce cross-shard resharding (fix boundary specs) / overlap collectives"


def markdown_table(mesh="single", tag=""):
    cells = load_cells(mesh, tag)
    lines = [
        "| arch × shape | compute s | memory s (HLO) | memory s (analytic) | "
        "collective s | dominant | 6ND/HLO | roofline frac | fits HBM | fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for name, c in sorted(cells.items()):
        if c.get("status") == "skipped":
            lines.append(f"| {name} | — | — | — | — | skipped | — | — | — | "
                         f"{c['reason'][:50]} |")
            continue
        if c.get("status") != "ok":
            lines.append(f"| {name} | — | — | — | — | ERROR | — | — | — | |")
            continue
        r = c["roofline"]
        lines.append(
            f"| {name} | {r['compute_s']:.4f} | {r['memory_s']:.3f} | "
            f"{r.get('memory_analytic_s', 0):.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | {c['fits_hbm']} | "
            f"{what_moves_it(r, c)} |"
        )
    return "\n".join(lines)


def roofline_rows(mesh="single"):
    rows = []
    for name, c in sorted(load_cells(mesh).items()):
        if c.get("status") == "ok":
            r = c["roofline"]
            rows.append((f"{name}.bound_s", r["bound_time_s"]))
            rows.append((f"{name}.dominant", r["dominant"]))
    return rows
