"""Paper-table benchmarks on the trained CNN (the faithful-reproduction
vehicle). One function per paper table/figure; each returns a list of
(row_name, value) and is registered with benchmarks.run.

Pipeline per variant (paper Fig. 4 order): BN fold → ReLU6→ReLU → CLE →
high-bias absorption → weight INT-k quant → bias correction → data-free
activation quant (β ± 6γ).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantSpec, channel_ranges, fake_quant, output_bias_error, sqnr_db

from ._cnn_pipeline import (
    adversarial_rescale,
    clip_weights,
    eval_accuracy,
    get_trained_cnn,
)

_STATE = {}


def _setup():
    if "model" in _STATE:
        return _STATE
    model, params = get_trained_cnn()
    folded = model.fold(params)
    hostile = adversarial_rescale(folded)          # the hard-to-quantize model
    _STATE.update(model=model, folded=folded, hostile=hostile)
    return _STATE


def _acc(model, folded, act_clip, *, w_bits=None, act_bits=8, act_sym=False,
         bias_correct=False, per_channel=False, sym_w=False, n_batches=6):
    spec = QuantSpec(bits=w_bits, symmetric=sym_w,
                     per_channel_axis=-1 if per_channel else None) if w_bits else None
    q = model.quantize_weights(folded, spec) if spec else folded
    if bias_correct and spec:
        q = model.bias_correct_analytic(folded, q, spec, act_clip=act_clip)
    return eval_accuracy(model, q, act_clip=act_clip, act_bits=act_bits,
                         act_symmetric=act_sym, n_batches=n_batches)


def table1_cle():
    """Paper Table 1: original / replace ReLU6 / +equalization / +absorbing
    bias / per-channel — FP32 and INT8 accuracy."""
    st = _setup()
    model, hostile = st["model"], st["hostile"]
    rows = []
    rows.append(("original_fp32", eval_accuracy(model, hostile, act_clip=6.0)))
    rows.append(("original_int8", _acc(model, hostile, 6.0, w_bits=8)))
    rows.append(("replace_relu6_fp32", eval_accuracy(model, hostile, act_clip=None)))
    rows.append(("replace_relu6_int8", _acc(model, hostile, None, w_bits=8)))
    eq = model.equalize(hostile)
    rows.append(("cle_fp32", eval_accuracy(model, eq, act_clip=None)))
    rows.append(("cle_int8", _acc(model, eq, None, w_bits=8)))
    ab = model.absorb_high_bias(eq)
    rows.append(("cle_absorb_fp32", eval_accuracy(model, ab, act_clip=None)))
    rows.append(("cle_absorb_int8", _acc(model, ab, None, w_bits=8)))
    rows.append(("per_channel_int8", _acc(model, hostile, 6.0, w_bits=8,
                                          per_channel=True)))
    return rows


def table2_bias_correction():
    """Paper Table 2: bias correction alone / clip@15 (+BC) / CLE+BA (+BC)."""
    st = _setup()
    model, hostile = st["model"], st["hostile"]
    rows = []
    rows.append(("original_int8", _acc(model, hostile, 6.0, w_bits=8)))
    rows.append(("bias_corr_int8", _acc(model, hostile, 6.0, w_bits=8,
                                        bias_correct=True)))
    clipped = clip_weights(hostile, 15.0)
    rows.append(("clip15_fp32", eval_accuracy(model, clipped, act_clip=6.0)))
    rows.append(("clip15_int8", _acc(model, clipped, 6.0, w_bits=8)))
    rows.append(("clip15_bias_corr_int8", _acc(model, clipped, 6.0, w_bits=8,
                                               bias_correct=True)))
    dfq = model.absorb_high_bias(model.equalize(hostile))
    rows.append(("cle_ba_int8", _acc(model, dfq, None, w_bits=8)))
    rows.append(("cle_ba_bc_int8 (full DFQ)", _acc(model, dfq, None, w_bits=8,
                                                   bias_correct=True)))
    return rows


def table5_bitwidths():
    """Paper Table 5 / Fig. 1: per-layer vs DFQ vs per-channel across INT8 /
    INT6 (and INT5/INT4 for the Fig. 1 sweep)."""
    st = _setup()
    model, hostile = st["model"], st["hostile"]
    dfq = model.absorb_high_bias(model.equalize(hostile))
    rows = []
    for bits in (8, 6, 5, 4):
        rows.append((f"per_layer_int{bits}", _acc(model, hostile, 6.0, w_bits=bits,
                                                  act_bits=max(bits, 8))))
        rows.append((f"dfq_int{bits}", _acc(model, dfq, None, w_bits=bits,
                                            act_bits=max(bits, 8), bias_correct=True)))
        rows.append((f"per_channel_int{bits}", _acc(model, hostile, 6.0, w_bits=bits,
                                                    act_bits=max(bits, 8),
                                                    per_channel=True)))
    return rows


def table6_analytic_vs_empirical():
    """Paper Table 6 (appendix D): analytic vs empirical bias correction."""
    from repro.data import synthetic_image_batch

    st = _setup()
    model, hostile = st["model"], st["hostile"]
    dfq = model.absorb_high_bias(model.equalize(hostile))
    spec = QuantSpec(bits=8)
    q = model.quantize_weights(dfq, spec)
    rows = [("no_bias_corr", eval_accuracy(model, q, act_clip=None, act_bits=8))]
    q_an = model.bias_correct_analytic(dfq, q, spec, act_clip=None)
    rows.append(("analytic_bc", eval_accuracy(model, q_an, act_clip=None, act_bits=8)))

    # empirical BC (appendix D): measure E[ỹ−y] layer-by-layer on calibration
    # images and fold into biases
    import copy

    calib = synthetic_image_batch(7, 0, 256, 32, 3, 8)["x"]
    q_emp = copy.deepcopy(jax.device_get(q))

    def act(h):
        return jax.nn.relu(h)

    h_fp = jnp.asarray(calib)
    h_q = jnp.asarray(calib)
    from repro.models.cnn import _conv

    def run_layer(folded_layer, h, stride=1, depthwise=False):
        w = jnp.asarray(folded_layer.w)
        groups = w.shape[-1] if depthwise else 1
        return _conv(h, w, stride, groups=groups) + jnp.asarray(folded_layer.b)

    # stem
    y_fp = run_layer(dfq["stem"], h_fp, 2)
    y_q = run_layer(q_emp["stem"], h_q, 2)
    err = jnp.mean(y_q - y_fp, axis=(0, 1, 2))
    q_emp["stem"] = q_emp["stem"]._replace(b=jnp.asarray(q_emp["stem"].b) - err)
    h_fp, h_q = act(y_fp), act(y_q - err)
    for i in range(len(dfq["blocks"])):
        for part, depthwise in (("expand", False), ("dw", True), ("project", False)):
            stride = dfq["blocks"][i]["stride"] if part == "dw" else 1
            y_fp = run_layer(dfq["blocks"][i][part], h_fp, stride, depthwise)
            y_q = run_layer(q_emp["blocks"][i][part], h_q, stride, depthwise)
            err = jnp.mean(y_q - y_fp, axis=(0, 1, 2))
            q_emp["blocks"][i][part] = q_emp["blocks"][i][part]._replace(
                b=jnp.asarray(q_emp["blocks"][i][part].b) - err)
            y_q = y_q - err
            if part == "project":
                h_fp, h_q = y_fp, y_q
            else:
                h_fp, h_q = act(y_fp), act(y_q)
    rows.append(("empirical_bc", eval_accuracy(model, q_emp, act_clip=None,
                                               act_bits=8)))
    return rows


def table7_sym_asym():
    """Paper Table 7 (appendix E): symmetric vs asymmetric after DFQ."""
    st = _setup()
    model, hostile = st["model"], st["hostile"]
    dfq = model.absorb_high_bias(model.equalize(hostile))
    return [
        ("dfq_symmetric", _acc(model, dfq, None, w_bits=8, sym_w=True,
                               act_sym=True, bias_correct=True)),
        ("dfq_asymmetric", _acc(model, dfq, None, w_bits=8, bias_correct=True)),
    ]


def table8_per_channel_plus_dfq():
    """Paper Table 8 (appendix E): DFQ components on top of per-channel."""
    st = _setup()
    model, hostile = st["model"], st["hostile"]
    cle = model.equalize(hostile)
    cle_ba = model.absorb_high_bias(cle)
    return [
        ("pc_original", _acc(model, hostile, 6.0, w_bits=8, per_channel=True)),
        ("pc_bias_corr", _acc(model, hostile, 6.0, w_bits=8, per_channel=True,
                              bias_correct=True)),
        ("pc_cle", _acc(model, cle, None, w_bits=8, per_channel=True)),
        ("pc_cle_ba_bc", _acc(model, cle_ba, None, w_bits=8, per_channel=True,
                              bias_correct=True)),
    ]


def fig2_channel_ranges():
    """Figs. 2/6: per-channel weight-range spread before/after CLE."""
    st = _setup()
    model, hostile = st["model"], st["hostile"]
    eq = model.equalize(hostile)

    def spread(folded):
        vals = []
        for blk in folded["blocks"]:
            r = channel_ranges(jnp.asarray(blk["dw"].w), -1)
            r = jnp.maximum(r, 1e-9)
            vals.append(float(jnp.max(r) / jnp.median(r)))
        return float(np.mean(vals))

    return [
        ("dw_range_spread_before (max/median)", spread(hostile)),
        ("dw_range_spread_after", spread(eq)),
    ]


def fig3_output_bias():
    """Fig. 3: per-channel biased output error before/after bias correction."""
    from repro.data import synthetic_image_batch
    from repro.models.cnn import _conv

    st = _setup()
    model, hostile = st["model"], st["hostile"]
    dfq = model.absorb_high_bias(model.equalize(hostile))
    spec = QuantSpec(bits=8)
    q = model.quantize_weights(dfq, spec)
    q_bc = model.bias_correct_analytic(dfq, q, spec, act_clip=None)

    x = synthetic_image_batch(11, 0, 128, 32, 3, 8)["x"]
    h = jax.nn.relu(_conv(x, jnp.asarray(dfq["stem"].w), 2) + jnp.asarray(dfq["stem"].b))
    blk_fp, blk_q, blk_bc = dfq["blocks"][1], q["blocks"][1], q_bc["blocks"][1]
    h2 = jax.nn.relu(_conv(h, jnp.asarray(dfq["blocks"][0]["expand"].w)) +
                     jnp.asarray(dfq["blocks"][0]["expand"].b))
    y_fp = _conv(h2, jnp.asarray(dfq["blocks"][0]["dw"].w), 1,
                 groups=h2.shape[-1]) + jnp.asarray(dfq["blocks"][0]["dw"].b)
    y_q = _conv(h2, jnp.asarray(q["blocks"][0]["dw"].w), 1,
                groups=h2.shape[-1]) + jnp.asarray(q["blocks"][0]["dw"].b)
    y_bc = _conv(h2, jnp.asarray(q_bc["blocks"][0]["dw"].w), 1,
                 groups=h2.shape[-1]) + jnp.asarray(q_bc["blocks"][0]["dw"].b)
    e_before = output_bias_error(y_fp, y_q)
    e_after = output_bias_error(y_fp, y_bc)
    return [
        ("dw_mean_abs_output_bias_before", float(jnp.mean(jnp.abs(e_before)))),
        ("dw_mean_abs_output_bias_after_bc", float(jnp.mean(jnp.abs(e_after)))),
    ]


ALL_TABLES = {
    "table1_cle": table1_cle,
    "table2_bias_correction": table2_bias_correction,
    "table5_bitwidths": table5_bitwidths,
    "table6_analytic_vs_empirical": table6_analytic_vs_empirical,
    "table7_sym_asym": table7_sym_asym,
    "table8_per_channel_plus_dfq": table8_per_channel_plus_dfq,
    "fig2_channel_ranges": fig2_channel_ranges,
    "fig3_output_bias": fig3_output_bias,
}
