"""Kernel micro-benchmarks.

Wall-time on this CPU container is NOT a TPU signal, so each kernel reports:
  * us_per_call of the XLA reference path on CPU (sanity/regression number),
  * derived TPU-roofline quantities: bytes moved, ideal v5e time at HBM bw,
    MXU-bound time at int8/bf16 peak, and the VMEM working set implied by
    the BlockSpec tiling (must be ≪ 16 MiB).

Results persist to ``BENCH_kernels.json`` (CI uploads it from the
bench-smoke job) so the kernel-perf trajectory is tracked across PRs:

    PYTHONPATH=src python benchmarks/kernels_bench.py [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HW_V5E
from repro.kernels.dispatch import count_pallas_calls
from repro.kernels.kv_attention.ref import kv_attention_ref, kv_attention_xla
from repro.kernels.qmatmul_w8a8.ref import qmatmul_w8a8_ref
from repro.kernels.qmatmul_w8a16.ref import qmatmul_w8a16_ref
from repro.kernels.quantize_act.ref import quantize_act_ref

DEFAULT_JSON = pathlib.Path(__file__).resolve().parent / "BENCH_kernels.json"


def _time(fn, *args, iters=5):
    out = fn(*args)                      # one warmup call, result reused
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_rows(smoke: bool = False):
    """``smoke`` shrinks every timed shape to CI-runner scale (seconds, tens
    of MB) while keeping identical code paths; the derived roofline rows
    always describe the production shapes."""
    rows = []
    # --- W8A8 prefill-shape GEMM: M=4096 tokens, K=N=4096 -----------------
    M, K, N = (512, 512, 512) if smoke else (4096, 4096, 4096)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a_q = jax.random.randint(ks[0], (M, K), -127, 128, dtype=jnp.int8)
    w_q = jax.random.randint(ks[1], (K, N), -127, 128, dtype=jnp.int8)
    f = jax.jit(lambda a, w: qmatmul_w8a8_ref(a, w, 0.01, 0.01))
    rows.append((f"w8a8_{M}x{K}x{N}.cpu_us", _time(f, a_q, w_q)))
    flops = 2 * 4096 ** 3
    rows.append(("w8a8.v5e_int8_mxu_bound_us",
                 flops / HW_V5E["peak_flops_int8"] * 1e6))
    rows.append(("w8a8.v5e_bf16_equiv_us",
                 flops / HW_V5E["peak_flops_bf16"] * 1e6))
    vmem = (128 * 512 + 512 * 128) * 1 + 128 * 128 * (4 + 4)
    rows.append(("w8a8.vmem_working_set_kib", vmem / 1024))

    # --- W8A16 decode-shape GEMM: M=8 (batch), big K,N ---------------------
    M, K, N = (8, 1024, 1024) if smoke else (8, 8192, 8192)
    a = jax.random.normal(ks[0], (M, K), jnp.bfloat16)
    w_q = jax.random.randint(ks[1], (K, N), -127, 128, dtype=jnp.int8)
    f = jax.jit(lambda a, w: qmatmul_w8a16_ref(a, w, 0.01))
    rows.append((f"w8a16_{M}x{K}x{N}.cpu_us", _time(f, a, w_q)))
    hbm_int8 = 8192 * 8192 * 1
    hbm_bf16 = 8192 * 8192 * 2
    rows.append(("w8a16.v5e_hbm_bound_us_int8_weights",
                 hbm_int8 / HW_V5E["hbm_bw"] * 1e6))
    rows.append(("w8a16.v5e_hbm_bound_us_bf16_weights",
                 hbm_bf16 / HW_V5E["hbm_bw"] * 1e6))
    rows.append(("w8a16.decode_weight_bytes_speedup", hbm_bf16 / hbm_int8))
    vmem = 8 * 1024 * 2 + 1024 * 512 * 1 + 8 * 512 * (4 + 2)
    rows.append(("w8a16.vmem_working_set_kib", vmem / 1024))

    # --- dynamic activation quantize ---------------------------------------
    M, K = (512, 1024) if smoke else (4096, 8192)
    x = jax.random.normal(ks[0], (M, K))
    f = jax.jit(lambda x: quantize_act_ref(x)[0])
    rows.append((f"quantize_act_{M}x{K}.cpu_us", _time(f, x)))
    rows.append(("quantize_act.v5e_hbm_bound_us",
                 (4096 * 8192 * 4 + 4096 * 8192 * 1) / HW_V5E["hbm_bw"] * 1e6))

    # --- int8-KV decode attention (one 32k-context token, 8 kv heads) ------
    B, S, H, hd = (2, 2048, 4, 64) if smoke else (8, 32768, 8, 128)
    kq = jax.random.randint(ks[0], (B, S, H, hd), -127, 128, dtype=jnp.int8)
    ksc = jax.random.uniform(ks[1], (B, S, H), minval=0.01, maxval=0.05)
    qv = jax.random.normal(ks[0], (B, H, hd))
    f = jax.jit(lambda q, kq, ksc: kv_attention_ref(q, kq, ksc, kq, ksc))
    rows.append((f"kv_attention_{B}x{S // 1024}k.cpu_us",
                 _time(f, qv, kq, ksc)))
    # the serving XLA path (scale folding at score granularity) with GQA:
    # 32 q heads read the same 8 kv heads without repeat-materialization
    qg = jax.random.normal(ks[0], (B, 4 * H, hd))
    f = jax.jit(lambda q, kq, ksc: kv_attention_xla(q, kq, ksc, kq, ksc))
    rows.append((f"kv_attention_gqa4_{B}x{S // 1024}k_xla.cpu_us",
                 _time(f, qg, kq, ksc)))
    B, S, H, hd = 8, 32768, 8, 128           # roofline: production shape
    cache_int8 = 2 * B * S * H * (hd * 1 + 4)
    cache_bf16 = 2 * B * S * H * hd * 2
    cache_fp32 = 2 * B * S * H * hd * 4
    rows.append(("kv_attention.v5e_cache_stream_us_int8",
                 cache_int8 / HW_V5E["hbm_bw"] * 1e6))
    rows.append(("kv_attention.v5e_cache_stream_us_bf16",
                 cache_bf16 / HW_V5E["hbm_bw"] * 1e6))
    rows.append(("kv_attention.cache_bytes_speedup_vs_bf16",
                 cache_bf16 / cache_int8))
    rows.append(("kv_attention.cache_bytes_speedup_vs_fp32",
                 cache_fp32 / cache_int8))
    vmem = 2 * 512 * H * hd * 1 + 2 * 512 * H * 4 + H * hd * 4
    rows.append(("kv_attention.vmem_working_set_kib", vmem / 1024))

    # --- fused decode megakernel: append-quantize + attention + q8-out -----
    # dispatch counts come from the traced jaxprs of the Pallas tier (exact
    # on CPU); wall time regresses the XLA composition the CPU path serves
    from repro.kernels.fused_decode.ops import fused_decode
    from repro.kernels.kv_attention.ops import kv_attention_decode, quantize_kv
    from repro.kernels.quantize_act.ops import quantize_act

    B, S, Hq, Hkv, hd = ((2, 512, 4, 2, 64) if smoke
                         else (8, 4096, 32, 8, 128))
    kk = jax.random.split(jax.random.PRNGKey(1), 4)
    qv = jax.random.normal(kk[0], (B, Hq, hd))
    kq, ksc = quantize_kv(jax.random.normal(kk[1], (B, S, Hkv, hd)))
    vq, vsc = quantize_kv(jax.random.normal(kk[2], (B, S, Hkv, hd)))
    k_new = jax.random.normal(kk[3], (B, 1, Hkv, hd))
    v_new = jax.random.normal(kk[0], (B, 1, Hkv, hd))
    idx = jnp.full((B, 1), S // 2, jnp.int32)
    valid = jnp.arange(S)[None, :] <= (S // 2)
    valid = jnp.broadcast_to(valid, (B, S))
    fused_n = count_pallas_calls(
        fused_decode, qv, kq, ksc, vq, vsc, k_new, v_new, idx,
        valid=valid, blk=min(512, S), backend="interpret", quantize_out=True)

    def stepwise(q, kq, ksc, vq, vsc, kn, vn, idx):
        out, upd = kv_attention_decode(q, kq, ksc, vq, vsc, kn, vn, idx,
                                       valid=valid, blk=min(512, S),
                                       backend="interpret")
        oq, os_ = quantize_act(out.reshape(out.shape[0], -1),
                               backend="interpret")
        return out, oq, os_, upd

    unfused_n = count_pallas_calls(stepwise, qv, kq, ksc, vq, vsc,
                                   k_new, v_new, idx)
    rows.append(("fused_decode.dispatches_per_step_fused", fused_n))
    rows.append(("fused_decode.dispatches_per_step_unfused", unfused_n))
    rows.append(("fused_decode.decode_dispatch_reduction",
                 unfused_n / fused_n))
    # q8 GEMM epilogue: the standalone quantize_act between a W8A8 GEMM and
    # its consumer folds into the GEMM's own launch
    rows.append(("qmatmul_q8_epilogue.dispatch_reduction", 2.0 / 1.0))
    f = jax.jit(lambda *a: fused_decode(*a, valid=valid, blk=min(512, S),
                                        backend="xla",
                                        quantize_out=True)[0][0])
    rows.append((f"fused_decode_{B}x{S}.xla_cpu_us",
                 _time(f, qv, kq, ksc, vq, vsc, k_new, v_new, idx)))
    return rows


def write_bench_json(path, rows, smoke: bool = False) -> None:
    payload = {
        "benchmark": "kernels",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "smoke": smoke,
        "rows": {name: float(value) for name, value in rows},
    }
    p = pathlib.Path(path)
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {p}")


def kernel_rows_persisted(json_path=None, smoke: bool = False):
    """benchmarks.run adapter: compute the rows AND persist them."""
    rows = kernel_rows(smoke=smoke)
    write_bench_json(json_path or DEFAULT_JSON, rows, smoke=smoke)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=str(DEFAULT_JSON), metavar="PATH",
                    help="where to persist machine-readable results")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny timed shapes for the CI smoke-benchmark job")
    args = ap.parse_args(argv)
    for name, value in kernel_rows_persisted(args.json, smoke=args.smoke):
        print(f"{name},{value}")


if __name__ == "__main__":
    main()
