"""Beyond-paper benchmark: DFQ on LM-family architectures (smoke scale).

For each family representative we (a) inject adversarial per-channel scales
into the exact-CLE pairs (function-preserving — the LLM analogue of the
hostile MobileNetV2 ranges), (b) quantize weights per-tensor INT8, and
(c) measure logit SQNR + greedy-token agreement vs FP32, for:
original-quantized / +CLE (apply_dfq) / +bias-correction / per-channel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro
from repro.configs import get_config
from repro.core import DFQConfig, sqnr_db
from repro.data import calibration_tokens
from repro.models import build_model

ARCHS = ["qwen2-0.5b", "mixtral-8x22b", "whisper-tiny", "mamba2-2.7b"]


from repro.core.adversarial import hostile_rescale as _lib_hostile


def _hostile(params, plan, seed=0, decades=1.5):
    return _lib_hostile(params, plan, seed=seed, decades=decades)


def _greedy_agreement(model, params_a, params_b, cfg, n=64):
    toks = calibration_tokens(3, 4, 16, cfg.vocab_size)
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(0), (4, cfg.enc_seq, cfg.d_model))
        la, _ = model.apply(params_a, toks, frames)
        lb, _ = model.apply(params_b, toks, frames)
    else:
        la, _ = model.apply(params_a, toks)
        lb, _ = model.apply(params_b, toks)
    agree = jnp.mean(jnp.argmax(la, -1) == jnp.argmax(lb, -1))
    return float(sqnr_db(la, lb)), float(agree)


def run_arch(arch: str):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = _hostile(params, model.dfq_plan(), decades=1.2)

    def q(recipe, **kw):
        return repro.quantize(model, params=params, recipe=recipe,
                              calib_batch=4, **kw).params

    rows = []
    q0 = q("naive-int8", calibration=None)
    snr, agree = _greedy_agreement(model, params, q0, cfg)
    rows.append((f"{arch}.per_tensor_int8_sqnr_db", snr))
    rows.append((f"{arch}.per_tensor_int8_top1_agree", agree))

    q1 = q(["fold_norm", "cle", "bias_absorb", "weight_quant"], calibration=None)
    snr, agree = _greedy_agreement(model, params, q1, cfg)
    rows.append((f"{arch}.dfq_cle_int8_sqnr_db", snr))
    rows.append((f"{arch}.dfq_cle_int8_top1_agree", agree))

    q2 = q("dfq-int8")
    snr, agree = _greedy_agreement(model, params, q2, cfg)
    rows.append((f"{arch}.dfq_cle_bc_int8_sqnr_db", snr))
    rows.append((f"{arch}.dfq_cle_bc_int8_top1_agree", agree))

    q3 = q("naive-int8", calibration=None,
           config=DFQConfig(per_channel=True))
    snr, agree = _greedy_agreement(model, params, q3, cfg)
    rows.append((f"{arch}.per_channel_int8_sqnr_db", snr))
    rows.append((f"{arch}.per_channel_int8_top1_agree", agree))
    return rows


def lm_dfq_all():
    rows = []
    for arch in ARCHS:
        rows.extend(run_arch(arch))
    return rows
